"""Compute-anchored megakernels: prologue/epilogue chains folded into
matmul and flash-attention Pallas bodies.

Covers the anchor pattern kind end to end: classification, the anchored
partition (fewer launches, more HBM saved than memory-only stitching),
numerics (fp32 exact vs the interpret oracle; bf16 within the widened
anchored band), plan-cache v6 round-trip plus the v5 degrade/upgrade
path, the ``REPRO_ANCHOR`` kill switch, and isomorphic anchored-group
emission dedup.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import StitchedFunction
from repro.core.classify import classify, vpu_cost
from repro.core.cost_model import anchor_enabled
from repro.core.ir import OpKind
from repro.core.plan_cache import FORMAT_VERSION, PlanCache
from repro.runtime import RUNG_ANCHORED
from repro.runtime.guard import (ANCHORED_VERIFY_TOLERANCES,
                                 VERIFY_TOLERANCES, tolerance_for)

rng = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# graphs
# ---------------------------------------------------------------------------
def _mlp(x, w, r):
    """Prologue chain -> matmul -> epilogue chain: one anchored group."""
    h = x * 2.0 + 1.0
    y = h @ w
    return jnp.tanh(y) + r


def _mlp_args(M=64, K=32, N=48, dtype=np.float32):
    return (rng.standard_normal((M, K)).astype(dtype),
            rng.standard_normal((K, N)).astype(dtype),
            rng.standard_normal((M, N)).astype(dtype))


def _attn(q, k, v, bias):
    """Scale + bias folded into the attention inner loop."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * 0.125 + bias
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def _attn_args(B=2, H=4, S=64, D=32):
    return (rng.standard_normal((B, H, S, D)).astype(np.float32),
            rng.standard_normal((B, H, S, D)).astype(np.float32),
            rng.standard_normal((B, H, S, D)).astype(np.float32),
            rng.standard_normal((1, 1, S, S)).astype(np.float32))


# ---------------------------------------------------------------------------
# classification (satellite: explicit kinds + vpu_cost)
# ---------------------------------------------------------------------------
def test_classify_anchor_kinds():
    assert classify("dot_general") is OpKind.ANCHOR
    assert classify("conv_general_dilated") is OpKind.ANCHOR
    # anchors are costed per *output* element, well above light EW ops
    assert vpu_cost("dot_general") > vpu_cost("add")
    assert vpu_cost("flash_attention") >= vpu_cost("dot_general")
    # non-anchor kinds are untouched
    assert classify("add") is OpKind.LIGHT_EW
    assert classify("reduce_sum") is OpKind.REDUCE
    assert classify("sort") is OpKind.OPAQUE


def test_anchor_knob_parsing(monkeypatch):
    monkeypatch.delenv("REPRO_ANCHOR", raising=False)
    assert anchor_enabled()
    for off in ("0", "off", "FALSE"):
        monkeypatch.setenv("REPRO_ANCHOR", off)
        assert not anchor_enabled()
    monkeypatch.setenv("REPRO_ANCHOR", "1")
    assert anchor_enabled()


# ---------------------------------------------------------------------------
# anchored matmul: numerics + plan shape
# ---------------------------------------------------------------------------
def test_matmul_anchored_exact_fp32():
    args = _mlp_args()
    sf = StitchedFunction(_mlp)
    rep = sf.report(*args)
    assert rep.n_anchored == 1
    assert rep.rung == RUNG_ANCHORED and not rep.fallbacks
    out = np.asarray(sf(*args))
    # anchored-vs-interpret is exact at fp32: same op order, same
    # accumulator, only the dispatch differs
    oracle = StitchedFunction(_mlp, dispatch="interpret")
    np.testing.assert_array_equal(out, np.asarray(oracle(*args)))
    # and the XLA reference agrees to float32 precision
    ref = np.asarray(_mlp(*(jnp.asarray(a) for a in args)))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_anchored_beats_memory_only_stitching(monkeypatch):
    """The anchored plan must launch fewer kernels and model strictly
    more HBM saved than the pure-memory partition of the same graph."""
    args = _mlp_args()
    monkeypatch.setenv("REPRO_ANCHOR", "0")
    rep_off = StitchedFunction(_mlp).report(*args)
    monkeypatch.setenv("REPRO_ANCHOR", "1")
    rep_on = StitchedFunction(_mlp).report(*args)
    assert rep_on.n_anchored >= 1 and rep_off.n_anchored == 0
    assert rep_on.stats.n_kernels_stitched < rep_off.stats.n_kernels_stitched
    assert rep_on.stitched_hbm_bytes_saved > rep_off.stitched_hbm_bytes_saved


def test_attention_bias_scale_folded():
    args = _attn_args()
    sf = StitchedFunction(_attn)
    rep = sf.report(*args)
    assert rep.n_anchored >= 1
    assert rep.rung == RUNG_ANCHORED and not rep.fallbacks
    out = np.asarray(sf(*args))
    ref = np.asarray(_attn(*(jnp.asarray(a) for a in args)))
    # the flash inner loop re-orders the softmax reduction (online
    # max/sum), so fp32 agreement is tight but not bitwise
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_attention_anchored_fewer_launches(monkeypatch):
    args = _attn_args()
    monkeypatch.setenv("REPRO_ANCHOR", "0")
    rep_off = StitchedFunction(_attn).report(*args)
    monkeypatch.setenv("REPRO_ANCHOR", "1")
    rep_on = StitchedFunction(_attn).report(*args)
    assert rep_on.stats.n_kernels_stitched < rep_off.stats.n_kernels_stitched
    assert rep_on.stitched_hbm_bytes_saved > rep_off.stitched_hbm_bytes_saved


# ---------------------------------------------------------------------------
# low precision: widened anchored verify band
# ---------------------------------------------------------------------------
def test_tolerance_for_anchored_band():
    # anchored widens only the low-precision dtypes
    assert tolerance_for(jnp.bfloat16, anchored=True) \
        == ANCHORED_VERIFY_TOLERANCES["bfloat16"]
    assert tolerance_for(jnp.float16, anchored=True) \
        == ANCHORED_VERIFY_TOLERANCES["float16"]
    assert tolerance_for(jnp.bfloat16, anchored=True)[1] \
        > tolerance_for(jnp.bfloat16)[1]
    # fp32 keeps the standard band either way
    assert tolerance_for(np.float32, anchored=True) \
        == VERIFY_TOLERANCES["float32"]
    assert tolerance_for(np.float32) == VERIFY_TOLERANCES["float32"]


def test_matmul_anchored_bf16():
    x, w, r = _mlp_args()
    args = tuple(jnp.asarray(a, dtype=jnp.bfloat16) for a in (x, w, r))
    sf = StitchedFunction(_mlp)
    rep = sf.report(*args)
    assert rep.n_anchored == 1
    out = np.asarray(sf(*args), dtype=np.float32)
    ref = np.asarray(_mlp(*args), dtype=np.float32)
    rtol, atol = ANCHORED_VERIFY_TOLERANCES["bfloat16"]
    np.testing.assert_allclose(out, ref, rtol=rtol, atol=atol)


def test_bf16_shadow_verification_passes(monkeypatch, tmp_path):
    """REPRO_VERIFY on an anchored bf16 dispatch uses the widened band:
    the run must not quarantine."""
    monkeypatch.setenv("REPRO_VERIFY", "first")
    x, w, r = _mlp_args()
    args = tuple(jnp.asarray(a, dtype=jnp.bfloat16) for a in (x, w, r))
    sf = StitchedFunction(_mlp, plan_cache=str(tmp_path))
    sf(*args)
    rep = sf.reports()[0]
    assert rep.n_anchored == 1
    assert rep.verified >= 1 and rep.verify_failures == 0
    assert not rep.quarantined


# ---------------------------------------------------------------------------
# plan cache: v6 round-trip, v5 degrade/upgrade, kill switch
# ---------------------------------------------------------------------------
def _entry_on_disk(cache_dir, signature):
    with open(os.path.join(cache_dir, f"{signature}.json")) as f:
        return json.load(f)


def test_plan_cache_v6_roundtrip(tmp_path):
    args = _mlp_args()
    sf1 = StitchedFunction(_mlp, plan_cache=str(tmp_path))
    rep1 = sf1.report(*args)
    y1 = np.asarray(sf1(*args))
    assert rep1.n_anchored == 1

    entry = _entry_on_disk(str(tmp_path), rep1.signature)
    # anchored mesh-free plans stay v6 even though FORMAT_VERSION moved
    # on (v7 is reserved for sharded plans)
    assert entry["format"] == 6 < FORMAT_VERSION
    anchored_recs = [g for g in entry["groups"] if g.get("anchors")]
    assert anchored_recs and all(
        isinstance(a, int) for g in anchored_recs for a in g["anchors"])

    sf2 = StitchedFunction(_mlp, plan_cache=str(tmp_path))
    rep2 = sf2.report(*args)
    assert rep2.plan_cache_hit
    assert rep2.n_anchored == rep1.n_anchored
    np.testing.assert_array_equal(np.asarray(sf2(*args)), y1)


def test_knob_off_writes_v5_and_signature_is_stable(monkeypatch, tmp_path):
    """``REPRO_ANCHOR=0`` reproduces the pre-anchor plan: a v5 entry
    with no anchor record anywhere, under the *same* graph signature
    (anchors hash as opaque, so toggling the knob never re-keys)."""
    args = _mlp_args()
    monkeypatch.setenv("REPRO_ANCHOR", "0")
    rep_off = StitchedFunction(_mlp, plan_cache=str(tmp_path)).report(*args)
    assert rep_off.n_anchored == 0
    entry = _entry_on_disk(str(tmp_path), rep_off.signature)
    assert entry["format"] == 5
    assert all("anchors" not in g for g in entry.get("groups", []))

    monkeypatch.setenv("REPRO_ANCHOR", "1")
    rep_on = StitchedFunction(_mlp).report(*args)
    assert rep_on.signature == rep_off.signature


def test_v5_entry_upgrades_in_place(monkeypatch, tmp_path):
    """A v5 (pre-anchor) entry loads, the absorbed anchored composition
    is rebuilt on top of it, and the entry is backfilled to v6."""
    args = _mlp_args()
    monkeypatch.setenv("REPRO_ANCHOR", "0")
    rep_off = StitchedFunction(_mlp, plan_cache=str(tmp_path)).report(*args)
    assert _entry_on_disk(str(tmp_path), rep_off.signature)["format"] == 5

    monkeypatch.setenv("REPRO_ANCHOR", "1")
    sf = StitchedFunction(_mlp, plan_cache=str(tmp_path))
    rep = sf.report(*args)
    assert rep.plan_cache_hit
    assert rep.n_anchored == 1
    upgraded = _entry_on_disk(str(tmp_path), rep.signature)
    assert upgraded["format"] == 6 < FORMAT_VERSION  # anchored, mesh-free
    assert any(g.get("anchors") for g in upgraded["groups"])
    ref = np.asarray(_mlp(*(jnp.asarray(a) for a in args)))
    np.testing.assert_allclose(np.asarray(sf(*args)), ref,
                               rtol=1e-5, atol=1e-5)


def test_v6_entry_degrades_under_kill_switch(monkeypatch, tmp_path):
    """A v6 anchored entry read with ``REPRO_ANCHOR=0`` must not revive
    the anchored composition -- the anchors re-plan as graph breaks and
    the answer stays right."""
    args = _mlp_args()
    rep1 = StitchedFunction(_mlp, plan_cache=str(tmp_path)).report(*args)
    assert _entry_on_disk(str(tmp_path), rep1.signature)["format"] == 6

    monkeypatch.setenv("REPRO_ANCHOR", "0")
    sf = StitchedFunction(_mlp, plan_cache=str(tmp_path))
    rep = sf.report(*args)
    assert rep.n_anchored == 0
    ref = np.asarray(_mlp(*(jnp.asarray(a) for a in args)))
    np.testing.assert_allclose(np.asarray(sf(*args)), ref,
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# emission dedup across isomorphic anchored groups
# ---------------------------------------------------------------------------
def test_isomorphic_anchored_layers_share_emission():
    w = (rng.standard_normal((64, 64)) * 0.05).astype(np.float32)

    def stack(x):
        for _ in range(4):
            x = jnp.tanh((x * 2.0 + 1.0) @ w)
        return x

    x = rng.standard_normal((16, 64)).astype(np.float32)
    sf = StitchedFunction(stack)
    rep = sf.report(x)
    assert rep.n_anchored >= 2
    assert rep.emission_reused >= 1, \
        "isomorphic anchored groups must rebind one compiled kernel"
    ref = np.asarray(stack(jnp.asarray(x)))
    np.testing.assert_allclose(np.asarray(sf(x)), ref, rtol=1e-5, atol=1e-5)
