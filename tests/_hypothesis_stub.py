"""Minimal stand-in for the `hypothesis` API surface the suite uses.

Installed by conftest only when the real package is missing, so the
property-based tests keep running (as seeded random sweeps instead of
shrinking searches) in minimal environments -- a hard top-level import
would otherwise break *collection* of every module that imports it.

Covers: given, settings, strategies.{integers, booleans, sampled_from,
composite}.  Each @given test runs ``max_examples`` deterministic draws.
"""
from __future__ import annotations

import functools
import random
import sys
import types


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(seq):
    items = list(seq)
    return _Strategy(lambda rng: items[rng.randrange(len(items))])


def composite(fn):
    @functools.wraps(fn)
    def build(*args, **kwargs):
        def draw_fn(rng):
            def draw(strategy):
                return strategy.example(rng)

            return fn(draw, *args, **kwargs)

        return _Strategy(draw_fn)

    return build


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strategies, **kw_strategies):
    def deco(fn):
        def wrapper():
            n = getattr(fn, "_stub_max_examples", 10)
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(n):
                drawn = [s.example(rng) for s in strategies]
                drawn_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*drawn, **drawn_kw)

        # no functools.wraps: pytest must see the zero-arg signature, not
        # the strategy-bound params of ``fn`` (it would demand fixtures).
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


def install() -> None:
    """Register stub ``hypothesis`` + ``hypothesis.strategies`` modules."""
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name, obj in (("integers", integers), ("booleans", booleans),
                      ("sampled_from", sampled_from), ("composite", composite)):
        setattr(st, name, obj)
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
