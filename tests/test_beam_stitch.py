"""ISSUE-3 tests: beam-search stitch partitioning (quality, determinism,
struct-keyed segment reuse), batched group-level measured autotune
(serial equivalence), plan-cache format v3 (tuned group schedules
round-trip, v2 entries degrade to re-tune), donation aliasing into the
first schedule item's kernel, and explicit VMEM scratch staging."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CostContext, Hardware, StitchedFunction, make_plan,
                        search_groups, trace)
from repro.core import autotune as autotune_mod
from repro.core.autotune import tune_group, tune_pattern
from repro.core.ir import FusionPlan, Pattern
from repro.core.plan_cache import PlanCache, entry_to_groups
from repro.core.stitcher import DEFAULT_BEAM_WIDTH, beam_width_from_env

rng = np.random.default_rng(29)


def _ln(x, g, b):
    m = jnp.mean(x, axis=-1, keepdims=True)
    v = jnp.mean((x - m) ** 2, axis=-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + 1e-5) * g + b


def _deep(x, g, b):
    for _ in range(8):
        x = _ln(x, g, b)
        x = jax.nn.gelu(x, approximate=True) + x
    return x


def _deep_args(R=64, C=512):
    return (rng.standard_normal((R, C)).astype(np.float32),
            (np.abs(rng.standard_normal(C)) + 0.5).astype(np.float32),
            rng.standard_normal(C).astype(np.float32))


def _waist(x, g, b):
    """Row stats -> wide waist -> combine: greedy's blind spot (the A+B
    union is VMEM-infeasible until the combine stage shrinks its IO)."""
    t = x * g + b
    s = jnp.mean(jnp.tanh(t), -1, keepdims=True)
    s2 = jnp.mean(t * t, -1, keepdims=True)
    r = jax.lax.rsqrt(s2 + 1e-5) * (s + 1.0)
    u = jnp.tanh(x * r)
    v = jax.nn.gelu(x + r, approximate=True)
    w_ = jnp.exp(x * 0.1) * r
    c = u * v + w_
    c = c + u * w_
    return c * 0.5 + jnp.tanh(c)


def _waist_case():
    R, C = 512, 2048
    x = rng.standard_normal((R, C)).astype(np.float32)
    g = (np.abs(rng.standard_normal(C)) + 0.5).astype(np.float32)
    b = rng.standard_normal(C).astype(np.float32)
    graph = trace(_waist, x, g, b)
    fus = sorted(graph.fusible_nodes())
    stats = [n for n in fus
             if graph.node(n).spec.shape[0] == R
             and (len(graph.node(n).spec.shape) == 1
                  or graph.node(n).spec.shape[-1] == 1)]
    a_end = max(stats)
    tail = [n for n in fus if n > a_end]
    b_end = tail[2 * len(tail) // 3 - 1]
    plan = FusionPlan([Pattern(frozenset(s), 0.0) for s in (
        [n for n in fus if n <= a_end],
        [n for n in fus if a_end < n <= b_end],
        [n for n in fus if n > b_end]) if s])
    return graph, plan, Hardware(vmem_bytes=160 * 1024)


def _partition_gain(ctx, groups) -> float:
    total = 0.0
    for grp in groups:
        if grp.stitched:
            total += ctx.stitch_gain(tuple(grp.parts)).latency_gain_s
    return total


# -- beam-search partition quality --------------------------------------------
def test_beam_never_worse_than_greedy():
    cases = []
    args = _deep_args()
    graph = trace(_deep, *args)
    cases.append((graph, make_plan(graph), None))
    cases.append(_waist_case())
    for graph, plan, hw in cases:
        ctx = CostContext(graph, hw)
        g1, s1 = search_groups(graph, plan, hw or ctx.hw, ctx=ctx,
                               beam_width=1)
        for width in (2, 4, 8):
            gw, sw = search_groups(graph, plan, hw or ctx.hw, ctx=ctx,
                                   beam_width=width)
            assert sw.gain_s >= s1.gain_s - 1e-15
            assert _partition_gain(ctx, gw) >= _partition_gain(ctx, g1) \
                - 1e-15


def test_beam_strictly_beats_greedy_on_waist():
    """Greedy refuses the infeasible A+B intermediate and never reaches
    the full merge; the beam holds it and wins strictly."""
    graph, plan, hw = _waist_case()
    ctx = CostContext(graph, hw)
    greedy, s1 = search_groups(graph, plan, hw, ctx=ctx, beam_width=1)
    beam, s4 = search_groups(graph, plan, hw, ctx=ctx, beam_width=4)
    assert s4.gain_s > s1.gain_s + 1e-12
    assert len(beam) < len(greedy)          # the full merge happened
    assert s4.beam_width == 4 and s4.states_explored > 0
    # both partitions cover exactly the plan's pattern members (plus any
    # absorbed leftovers), each pattern exactly once
    covered = [n for grp in beam for p in grp.parts for n in p]
    assert len(covered) == len(set(covered))
    plan_members = {n for p in plan.patterns for n in p.members}
    assert plan_members <= set(covered)


def test_beam_deterministic_across_runs():
    graph, plan, hw = _waist_case()
    runs = []
    for _ in range(2):  # fresh context: no shared memoization between runs
        ctx = CostContext(graph, hw)
        groups, stats = search_groups(graph, plan, hw, ctx=ctx,
                                      beam_width=4)
        runs.append(([tuple(sorted(p) for p in grp.parts)
                      for grp in groups],
                     stats.gain_s, stats.states_explored))
    assert runs[0] == runs[1]

    args = _deep_args()
    graph2 = trace(_deep, *args)
    plans = [make_plan(graph2, ctx=CostContext(graph2)) for _ in range(2)]
    parts = []
    for plan2 in plans:
        groups, _ = search_groups(graph2, plan2,
                                  ctx=CostContext(graph2), beam_width=4)
        parts.append([tuple(sorted(p) for p in grp.parts)
                      for grp in groups])
    assert parts[0] == parts[1]


def test_beam_width_env_knob(monkeypatch):
    monkeypatch.delenv("REPRO_STITCH_BEAM", raising=False)
    assert beam_width_from_env() == DEFAULT_BEAM_WIDTH
    monkeypatch.setenv("REPRO_STITCH_BEAM", "7")
    assert beam_width_from_env() == 7
    monkeypatch.setenv("REPRO_STITCH_BEAM", "0")
    assert beam_width_from_env() == 1          # clamped to greedy
    monkeypatch.setenv("REPRO_STITCH_BEAM", "bogus")
    assert beam_width_from_env() == DEFAULT_BEAM_WIDTH


def test_isomorphic_segments_replay_partition():
    """Repeated blocks separated by opaque matmuls: later isomorphic
    segments replay the first one's searched partition."""
    C = 256
    w = (np.eye(C) * 0.9).astype(np.float32)

    def block(x, g, b):
        for _ in range(5):
            x = _ln(x, g, b)
            x = jax.nn.gelu(x, approximate=True) + x
        return x

    def stack(x, g, b):
        for _ in range(6):
            x = block(x, g, b) @ w
        return x

    args = _deep_args(16, C)
    graph = trace(stack, *args)
    ctx = CostContext(graph)
    plan = make_plan(graph, ctx=ctx)
    groups, stats = search_groups(graph, plan, ctx=ctx, beam_width=4)
    assert stats.segments >= 6
    assert stats.segments_reused >= 1       # middle blocks replayed
    assert sum(1 for g in groups if g.stitched) >= 6


def test_report_carries_beam_fields():
    args = _deep_args()
    rep = StitchedFunction(_deep).report(*args)
    assert rep.beam_width == DEFAULT_BEAM_WIDTH
    assert rep.beam_states_explored > 0


# -- batched vs serial autotune ----------------------------------------------
def _fake_timer(scores):
    """Deterministic _time_callable stand-in keyed on the candidate."""
    def timer(fn, args, *, warmup=1, iters=3, key=None):
        assert key is not None
        return scores.get(dict(key).get("schedule"), 99.0) \
            + dict(key).get("block_rows", 0) * 1e-3
    return timer


def test_batched_and_serial_sweeps_agree(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "force")
    args = _deep_args()
    graph = trace(_deep, *args)
    ctx = CostContext(graph)
    plan = make_plan(graph, ctx=ctx)
    groups, _ = search_groups(graph, plan, ctx=ctx)
    grp = max(groups, key=len)
    assert grp.stitched
    # deterministic timing: onepass beats streaming, small blocks win
    monkeypatch.setattr(autotune_mod, "_time_callable",
                        _fake_timer({"onepass": 1.0, "streaming": 2.0}))
    over_b = tune_group(graph, grp.parts, ctx=ctx, batch_compile=True)
    over_s = tune_group(graph, grp.parts, ctx=ctx, batch_compile=False)
    assert over_b == over_s
    assert over_b is not None and over_b["schedule"] == "onepass"
    # flipped preference: both paths must follow
    monkeypatch.setattr(autotune_mod, "_time_callable",
                        _fake_timer({"onepass": 2.0, "streaming": 1.0}))
    over_b2 = tune_group(graph, grp.parts, ctx=ctx, batch_compile=True)
    over_s2 = tune_group(graph, grp.parts, ctx=ctx, batch_compile=False)
    assert over_b2 == over_s2
    assert over_b2["schedule"] == "streaming"
    # pattern-level sweep agrees across paths too
    pat = plan.patterns[0].members
    assert tune_pattern(graph, pat, ctx=ctx, batch_compile=True) \
        == tune_pattern(graph, pat, ctx=ctx, batch_compile=False)


def test_group_tune_measures_real_kernels():
    """Unmocked batched sweep returns a candidate that actually emits."""
    args = _deep_args(16, 256)
    graph = trace(_deep, *args)
    ctx = CostContext(graph)
    plan = make_plan(graph, ctx=ctx)
    groups, _ = search_groups(graph, plan, ctx=ctx)
    grp = max(groups, key=len)
    over = tune_group(graph, grp.parts, ctx=ctx, batch_compile=True)
    assert over is not None
    assert over["schedule"] in ("onepass", "streaming")
    assert over.get("block_rows", 0) > 0


# -- plan-cache format v3 ------------------------------------------------------
def test_tuned_group_schedule_roundtrips_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "force")
    args = _deep_args()
    sf1 = StitchedFunction(_deep, autotune=True, plan_cache=str(tmp_path))
    rep1 = sf1.report(*args)
    assert rep1.autotuned and rep1.group_tuned >= 1

    entry = PlanCache(str(tmp_path)).load(rep1.signature)
    # _deep has no anchors, so the entry persists as v5 (v6 is reserved
    # for plans carrying anchored groups)
    assert entry is not None and entry["format"] == 5
    tuned_recs = [r for r in entry["groups"] if r.get("tuned")]
    assert tuned_recs and all(
        r["schedule"] in ("onepass", "streaming") for r in tuned_recs)

    # second process: the measured pin is trusted, not re-measured
    calls = []
    real = autotune_mod.tune_group

    def counting(*a, **k):
        calls.append(a)
        return real(*a, **k)

    monkeypatch.setattr(autotune_mod, "tune_group", counting)
    sf2 = StitchedFunction(_deep, autotune=True, plan_cache=str(tmp_path))
    rep2 = sf2.report(*args)
    assert rep2.plan_cache_hit and rep2.group_tuned >= 1
    assert not calls                       # no re-measurement happened
    np.testing.assert_allclose(np.asarray(sf2(*args)),
                               np.asarray(sf1(*args)),
                               rtol=1e-6, atol=1e-6)


def test_v2_entry_degrades_to_retune(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "force")
    args = _deep_args()
    sf1 = StitchedFunction(_deep, autotune=True, plan_cache=str(tmp_path))
    rep1 = sf1.report(*args)
    path = os.path.join(str(tmp_path), f"{rep1.signature}.json")
    with open(path) as f:
        entry = json.load(f)
    entry["format"] = 2                    # downgrade: strip v3-only bits
    entry.pop("checksum", None)            # pre-checksum era had none
    for r in entry["groups"]:
        r.pop("tuned", None)
    with open(path, "w") as f:
        json.dump(entry, f)

    graph = trace(_deep, *args)
    from repro.core.plan_cache import entry_to_plan
    plan, _ = entry_to_plan(entry, graph)
    decoded = entry_to_groups(entry, plan, graph)
    assert decoded is not None             # composition loads...
    _, overrides = decoded
    assert all(o == {} for o in overrides)  # ...but schedules are dropped

    sf2 = StitchedFunction(_deep, autotune=True, plan_cache=str(tmp_path))
    rep2 = sf2.report(*args)
    assert rep2.plan_cache_hit             # no failure, plan reused
    assert rep2.group_tuned >= 1           # groups were re-tuned
    # and the entry was upgraded back to the current format on disk
    upgraded = PlanCache(str(tmp_path)).load(rep1.signature)
    assert upgraded["format"] == 5         # anchor-free: native format
    assert any(r.get("tuned") for r in upgraded["groups"])
    np.testing.assert_allclose(np.asarray(sf2(*args)),
                               np.asarray(_deep(*(jnp.asarray(a)
                                                  for a in args))),
                               rtol=1e-4, atol=1e-4)


# -- donation aliasing + explicit scratch staging ------------------------------
@pytest.mark.filterwarnings("ignore:Some donated buffers were not usable")
def test_first_kernel_aliases_donated_inputs():
    args = _deep_args()
    sf = StitchedFunction(_deep, donate=True)
    compiled = sf.compiled(*args)
    kernels = [em for kind, em in compiled.schedule if kind == "pattern"]
    assert kernels[0].io_aliases          # x donated into the output
    assert set(kernels[0].io_aliases.values()) <= set(
        range(len(kernels[0].out_ids)))
    y = np.asarray(sf(*args))
    ref = np.asarray(_deep(*(jnp.asarray(a) for a in args)))
    np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)

    # without donate=, no kernel-level aliasing either
    base = StitchedFunction(_deep).compiled(*args)
    assert all(not em.io_aliases
               for kind, em in base.schedule if kind == "pattern")

    # an input that is also consumed by a later schedule item (here: a
    # graph output passthrough) must not be aliased into the kernel
    def passthrough(x, g):
        return x, jnp.tanh(x * g) + x
    x = rng.standard_normal((8, 128)).astype(np.float32)
    g = np.ones(128, np.float32)
    cp = StitchedFunction(passthrough, donate=True).compiled(x, g)
    for kind, em in cp.schedule:
        if kind == "pattern" and em.io_aliases:
            xpos = [i for i, e in enumerate(em.ext_ids) if e == 0]
            assert not xpos or xpos[0] not in em.io_aliases


def test_group_emission_uses_explicit_scratch():
    args = _deep_args()
    sf = StitchedFunction(_deep)
    compiled = sf.compiled(*args)
    kernels = [em for kind, em in compiled.schedule if kind == "pattern"]
    stitched = [em for em in kernels if len(em.parts) > 1]
    assert stitched and any(em.staged_slots > 0 for em in stitched)
    y = np.asarray(sf(*args))
    ref = np.asarray(_deep(*(jnp.asarray(a) for a in args)))
    np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)
