"""ISSUE-1 tests: cost-context equivalence, single-dispatch executables,
and the persistent plan/tuning cache."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import trace
from repro.core.costctx import CostContext, NullContext, PatternBounds
from repro.core.ir import FUSIBLE_KINDS
from repro.core.plan_cache import (FORMAT_VERSION, PlanCache, entry_to_plan,
                                   graph_signature, plan_to_entry)
from repro.core.planner import make_plan
from repro.core.stitch import StitchedFunction, stitched_jit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
rng = np.random.default_rng(7)


def layernorm(x, g, b):
    m = jnp.mean(x, axis=-1, keepdims=True)
    v = jnp.mean((x - m) ** 2, axis=-1, keepdims=True)
    return (x - m) * jax.lax.rsqrt(v + 1e-5) * g + b


def softmax(x):
    s = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(s)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def rmsnorm(x, g):
    v = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(v + 1e-6) * g


def mini_transformer(x, g1, b1, w1, w2):
    h = layernorm(x, g1, b1)
    u = jax.nn.gelu(h @ w1, approximate=True)
    return softmax(x + u @ w2)


def _args(name):
    x = rng.standard_normal((16, 128)).astype(np.float32)
    g = np.abs(rng.standard_normal(128)).astype(np.float32) + 0.5
    b = rng.standard_normal(128).astype(np.float32)
    if name == "layernorm":
        return layernorm, (x, g, b)
    if name == "softmax":
        return softmax, (x,)
    if name == "rmsnorm":
        return rmsnorm, (x, g)
    w1 = (rng.standard_normal((128, 64)) * 0.05).astype(np.float32)
    w2 = (rng.standard_normal((64, 128)) * 0.05).astype(np.float32)
    return mini_transformer, (x, g, b, w1, w2)


# -- cost context vs seed-mode equivalence -----------------------------------
@pytest.mark.parametrize("name", ["layernorm", "softmax", "mini_transformer"])
def test_ctx_and_nullctx_plans_identical(name):
    fn, args = _args(name)
    graph = trace(fn, *args)
    p1 = make_plan(graph, ctx=CostContext(graph))
    p2 = make_plan(graph, ctx=NullContext(graph))
    assert sorted(map(sorted, (p.members for p in p1.patterns))) == \
        sorted(map(sorted, (p.members for p in p2.patterns)))


def test_bitset_convexity_matches_bfs():
    fn, args = _args("mini_transformer")
    graph = trace(fn, *args)
    fusible = graph.fusible_nodes()
    prng = np.random.default_rng(0)
    for _ in range(200):
        k = int(prng.integers(2, 9))
        pat = frozenset(prng.choice(fusible, size=k, replace=False).tolist())
        assert graph.is_convex(pat) == graph.is_convex_bfs(pat)


def test_union_bounds_match_scratch_compute():
    fn, args = _args("mini_transformer")
    graph = trace(fn, *args)
    ctx = CostContext(graph)
    fusible = sorted(graph.fusible_nodes())
    a = frozenset(fusible[:4])
    b = frozenset(fusible[3:8])
    u = ctx.union(a, b)
    got = ctx.bounds(u)
    want = PatternBounds.compute(graph, u, frozenset(graph.outputs))
    assert got == want


# -- single-dispatch executables ---------------------------------------------
@pytest.mark.parametrize("name", ["layernorm", "softmax", "rmsnorm",
                                  "mini_transformer"])
def test_single_dispatch_matches_interpreter(name):
    fn, args = _args(name)
    single = StitchedFunction(fn, dispatch="single")
    interp = StitchedFunction(fn, dispatch="interpret")
    y1 = np.asarray(single(*args))
    y2 = np.asarray(interp(*args))
    ref = np.asarray(fn(*(jnp.asarray(a) for a in args)))
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(y1, ref, rtol=1e-4, atol=1e-5)


def test_single_dispatch_is_one_python_call():
    fn, args = _args("mini_transformer")
    sf = StitchedFunction(fn, dispatch="single")
    compiled = sf.compiled(*args)
    for _ in range(3):
        sf(*args)
    # the schedule body ran in Python exactly once (at jit trace time)
    assert compiled.exec_count == 1
    # while the seed-style interpreter re-enters Python per call
    si = StitchedFunction(fn, dispatch="interpret")
    ci = si.compiled(*args)
    for _ in range(3):
        si(*args)
    assert ci.exec_count == 3


def test_single_dispatch_composes_under_jit_and_grad():
    fn, args = _args("rmsnorm")
    wrapped = stitched_jit(fn, differentiable=True)
    y = jax.jit(wrapped)(*args)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(fn(*(jnp.asarray(a) for a in args))),
        rtol=1e-4, atol=1e-5)
    g1 = jax.grad(lambda *a: jnp.sum(wrapped(*a)))(*args)
    g2 = jax.grad(lambda *a: jnp.sum(fn(*a)))(*(jnp.asarray(a)
                                                for a in args))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-3, atol=1e-4)


# -- persistent plan cache ----------------------------------------------------
def test_graph_signature_structural():
    fn, args = _args("layernorm")
    g1 = trace(fn, *args)
    g2 = trace(fn, *args)
    from repro.core.cost_model import V5E
    assert graph_signature(g1, V5E) == graph_signature(g2, V5E)
    # different shape -> different signature
    x2 = rng.standard_normal((16, 256)).astype(np.float32)
    g3 = trace(fn, x2, np.ones(256, np.float32), np.zeros(256, np.float32))
    assert graph_signature(g1, V5E) != graph_signature(g3, V5E)


def test_plan_cache_roundtrip(tmp_path):
    fn, args = _args("layernorm")
    graph = trace(fn, *args)
    from repro.core.cost_model import V5E
    sig = graph_signature(graph, V5E)
    plan = make_plan(graph)
    schedules = [{"schedule": "onepass", "block_rows": 8}
                 for _ in plan.patterns]
    cache = PlanCache(str(tmp_path))
    cache.store(sig, plan_to_entry(plan, schedules, sig))
    entry = cache.load(sig)
    # a pattern-only entry carries no anchored groups -> native v5
    assert entry is not None and entry["format"] == 5
    decoded = entry_to_plan(entry, graph)
    assert decoded is not None
    plan2, overrides = decoded
    assert [sorted(p.members) for p in plan2.patterns] == \
        [sorted(p.members) for p in plan.patterns]
    assert overrides[0]["block_rows"] == 8


def test_graph_signature_covers_remote_fusion_flag():
    fn, args = _args("layernorm")
    graph = trace(fn, *args)
    from repro.core.cost_model import V5E
    assert graph_signature(graph, V5E, remote_fusion=True) != \
        graph_signature(graph, V5E, remote_fusion=False)


def test_plan_cache_roundtrips_streaming_block_cols(tmp_path):
    fn, args = _args("layernorm")
    graph = trace(fn, *args)
    from repro.core.cost_model import V5E
    sig = graph_signature(graph, V5E)
    plan = make_plan(graph)
    schedules = [{"schedule": "streaming", "block_rows": 8,
                  "block_cols": 512} for _ in plan.patterns]
    cache = PlanCache(str(tmp_path))
    cache.store(sig, plan_to_entry(plan, schedules, sig))
    _, overrides = entry_to_plan(cache.load(sig), graph)
    assert overrides[0] == {"schedule": "streaming", "block_rows": 8,
                            "block_cols": 512}


def test_plan_cache_rejects_stale_entry(tmp_path):
    fn, args = _args("layernorm")
    graph = trace(fn, *args)
    entry = {"format": FORMAT_VERSION, "signature": "x",
             "patterns": [{"members": [99999]}]}
    assert entry_to_plan(entry, graph) is None        # unknown node
    entry = {"format": 1, "patterns": []}
    assert entry_to_plan(entry, graph) is None        # unsupported version
    # v2 is *supported* (degrades to re-tuning groups), not rejected
    entry = {"format": 2, "signature": "x", "patterns": []}
    assert entry_to_plan(entry, graph) is not None


def test_plan_cache_tolerates_malformed_files_and_fields(tmp_path):
    fn, args = _args("layernorm")
    graph = trace(fn, *args)
    from repro.core.cost_model import V5E
    sig = graph_signature(graph, V5E)
    cache = PlanCache(str(tmp_path))
    # valid JSON that is not a dict must be treated as a miss, not crash
    with open(os.path.join(str(tmp_path), f"{sig}.json"), "w") as f:
        f.write("[1, 2]")
    assert cache.load(sig) is None
    # malformed schedule fields degrade to the analytic sweep
    plan = make_plan(graph)
    entry = plan_to_entry(
        plan, [{"schedule": "streaming", "block_rows": "abc",
                "block_cols": None} for _ in plan.patterns], sig)
    decoded = entry_to_plan(entry, graph)
    assert decoded is not None
    assert decoded[1][0] == {"schedule": "streaming"}
    entry = plan_to_entry(
        plan, [{"schedule": "bogus", "block_rows": 8}
               for _ in plan.patterns], sig)
    assert entry_to_plan(entry, graph)[1][0] == {}


def test_in_process_cache_hit_same_signature(tmp_path):
    fn, args = _args("rmsnorm")
    sf1 = StitchedFunction(fn, plan_cache=str(tmp_path))
    rep1 = sf1.report(*args)
    assert not rep1.plan_cache_hit
    # new StitchedFunction, same process: hits the on-disk entry
    sf2 = StitchedFunction(fn, plan_cache=str(tmp_path))
    rep2 = sf2.report(*args)
    assert rep2.plan_cache_hit
    assert rep2.signature == rep1.signature
    assert sorted(map(sorted, rep2.patterns)) == \
        sorted(map(sorted, rep1.patterns))
    np.testing.assert_allclose(np.asarray(sf2(*args)),
                               np.asarray(fn(*(jnp.asarray(a)
                                               for a in args))),
                               rtol=1e-4, atol=1e-5)


_FRESH_PROC = textwrap.dedent("""
    import json, sys
    import numpy as np
    import jax.numpy as jnp
    import jax
    from repro.core import explorer
    from repro.core.stitch import StitchedFunction

    def layernorm(x, g, b):
        m = jnp.mean(x, axis=-1, keepdims=True)
        v = jnp.mean((x - m) ** 2, axis=-1, keepdims=True)
        return (x - m) * jax.lax.rsqrt(v + 1e-5) * g + b

    rng = np.random.default_rng(3)
    x = rng.standard_normal((32, 256)).astype(np.float32)
    g = np.ones(256, np.float32)
    b = np.zeros(256, np.float32)
    sf = StitchedFunction(layernorm, plan_cache=sys.argv[1])
    rep = sf.report(x, g, b)
    y = np.asarray(sf(x, g, b))
    ref = np.asarray(layernorm(jnp.asarray(x), g, b))
    print(json.dumps({
        "cache_hit": rep.plan_cache_hit,
        "explore_runs": explorer.EXPLORE_RUNS,
        "signature": rep.signature,
        "max_err": float(np.max(np.abs(y - ref))),
    }))
""")


def test_plan_cache_hits_across_processes(tmp_path):
    """Second compile of an identical graph signature in a *fresh process*
    hits the persistent cache and skips exploration entirely."""
    env = dict(os.environ, PYTHONPATH=SRC)
    results = []
    for _ in range(2):
        proc = subprocess.run(
            [sys.executable, "-c", _FRESH_PROC, str(tmp_path)],
            env=env, capture_output=True, text=True, timeout=300)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        results.append(json.loads(proc.stdout.strip().splitlines()[-1]))
    first, second = results
    assert not first["cache_hit"] and first["explore_runs"] >= 1
    assert second["cache_hit"]
    assert second["explore_runs"] == 0       # exploration skipped
    assert second["signature"] == first["signature"]
    assert second["max_err"] < 1e-4


# -- measured autotune (forced on CPU) ----------------------------------------
def test_autotune_forced_produces_valid_override(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_AUTOTUNE", "force")
    fn, args = _args("rmsnorm")
    sf = StitchedFunction(fn, autotune=True, plan_cache=str(tmp_path))
    rep = sf.report(*args)
    assert rep.autotuned
    np.testing.assert_allclose(np.asarray(sf(*args)),
                               np.asarray(fn(*(jnp.asarray(a)
                                               for a in args))),
                               rtol=1e-4, atol=1e-5)
    # tuned schedule was persisted
    sf2 = StitchedFunction(fn, plan_cache=str(tmp_path))
    assert sf2.report(*args).plan_cache_hit
