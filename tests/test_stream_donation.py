"""ISSUE-4 satellite: phase-aware donation for streaming kernels.

The onepass-only aliasing policy both *missed legal donations* (a FULL
input whose block index map follows the output's is safe to overwrite
in the streaming grid) and -- had it been naively extended -- *would
have corrupted re-read inputs* (a ROW input's block is pinned at
``(i, 0)`` and re-read by every column tile of the final phase, after
the first aliased write has already landed on it).  These tests pin
both sides of the legality line.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CostContext, trace
from repro.core.codegen import _alias_map, _alias_map_streaming, emit_pattern
from repro.core.ir import OpKind

rng = np.random.default_rng(53)


def _full_fn(x, g):
    return jnp.tanh(x) * g + x * 0.5


def _row_fn(x, s):
    t = x * s
    r = jnp.sum(t, -1, keepdims=True)
    return r * s


def _pattern_io(graph, ctx, pattern):
    b = ctx.bounds(pattern)
    ext_ids = [i for i in b.inputs
               if graph.node(i).kind is not OpKind.CONST]
    return ext_ids, list(b.outputs)


def test_streaming_full_alias_taken_and_correct():
    """A FULL input consumed only inside the kernel now donates into the
    streaming kernel's output buffer (previously: streaming kernels
    never took ``input_output_aliases`` at all) -- and the multi-tile
    grid still produces correct results."""
    x = rng.standard_normal((8, 256)).astype(np.float32)
    g = (np.abs(rng.standard_normal(256)) + 0.5).astype(np.float32)
    graph = trace(_full_fn, x, g)
    ctx = CostContext(graph)
    pattern = frozenset(graph.fusible_nodes())
    x_id = graph.inputs[0]
    em = emit_pattern(graph, pattern, ctx=ctx,
                      schedule_override={"schedule": "streaming",
                                         "block_rows": 4,
                                         "block_cols": 128},
                      donate_into=frozenset({x_id}))
    assert em.estimate.schedule == "streaming"
    assert em.io_aliases                  # the legal donation is taken
    aliased_ext = [em.ext_ids[i] for i in em.io_aliases]
    assert aliased_ext == [x_id]
    (y,) = em.fn(jnp.asarray(x), jnp.asarray(g))
    ref = _full_fn(jnp.asarray(x), jnp.asarray(g))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # without donate_into, no aliasing (the pre-existing default)
    em0 = emit_pattern(graph, pattern, ctx=ctx,
                       schedule_override={"schedule": "streaming",
                                          "block_rows": 4,
                                          "block_cols": 128})
    assert not em0.io_aliases


def test_streaming_row_alias_refused_where_naive_would_corrupt():
    """The naive (onepass) alias map WOULD donate the ROW input into the
    ROW output; the phase-aware check must refuse it whenever the row
    spans more than one column tile (the block is re-read at tiles
    ``j >= 1`` of the final phase, after the write at ``j == 0``)."""
    x = rng.standard_normal((8, 256)).astype(np.float32)
    s = rng.standard_normal((8, 1)).astype(np.float32)
    graph = trace(_row_fn, x, s)
    ctx = CostContext(graph)
    pattern = frozenset(graph.fusible_nodes())
    info = ctx.info(pattern)
    assert info is not None
    s_id = graph.inputs[1]
    ext_ids, out_ids = _pattern_io(graph, ctx, pattern)
    donate = frozenset({s_id})

    naive = _alias_map(graph, info, ext_ids, out_ids, donate)
    assert naive                          # onepass logic says "alias it"
    # ...but with 2 column tiles the final phase re-reads the block
    # after writing it: the phase-aware check refuses
    assert _alias_map_streaming(graph, info, ext_ids, out_ids, donate,
                                block_cols=128, phases=2) is None
    # a single column tile defers the write-back past every read: legal
    assert _alias_map_streaming(graph, info, ext_ids, out_ids, donate,
                                block_cols=256, phases=2)

    # the emitted multi-tile streaming kernel carries no alias and stays
    # correct even when asked to donate the ROW input
    em = emit_pattern(graph, pattern, ctx=ctx,
                      schedule_override={"schedule": "streaming",
                                         "block_rows": 4,
                                         "block_cols": 128},
                      donate_into=donate)
    assert em.estimate.schedule == "streaming"
    assert not em.io_aliases
    (y,) = em.fn(jnp.asarray(x), jnp.asarray(s))
    ref = _row_fn(jnp.asarray(x), jnp.asarray(s))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def _softmax_like(x, g):
    e = jnp.exp(x - jnp.max(x, axis=-1, keepdims=True))
    return e / jnp.sum(e, axis=-1, keepdims=True) * g


def test_streaming_multiphase_full_alias_refused_across_tiles():
    """phases >= 2 with several column tiles: Pallas flushes the output
    window whenever its block index changes -- including after phase-0
    cells the kernel never stored to -- so an aliased FULL input's
    tiles would be clobbered before phase 1 re-reads them.  Refused;
    a single column tile (write-back deferred until the next row
    block) stays legal."""
    x = rng.standard_normal((8, 256)).astype(np.float32)
    g = (np.abs(rng.standard_normal(256)) + 0.5).astype(np.float32)
    graph = trace(_softmax_like, x, g)
    ctx = CostContext(graph)
    pattern = frozenset(graph.fusible_nodes())
    info = ctx.info(pattern)
    assert info is not None
    x_id = graph.inputs[0]
    ext_ids, out_ids = _pattern_io(graph, ctx, pattern)
    donate = frozenset({x_id})
    assert _alias_map_streaming(graph, info, ext_ids, out_ids, donate,
                                block_cols=128, phases=3) is None
    assert _alias_map_streaming(graph, info, ext_ids, out_ids, donate,
                                block_cols=256, phases=3)
    # the emitter derives phases itself and must refuse the multi-tile
    # donation while staying correct
    em = emit_pattern(graph, pattern, ctx=ctx,
                      schedule_override={"schedule": "streaming",
                                         "block_rows": 4,
                                         "block_cols": 128},
                      donate_into=donate)
    assert em.estimate.schedule == "streaming"
    assert not em.io_aliases
    (y,) = em.fn(jnp.asarray(x), jnp.asarray(g))
    ref = _softmax_like(jnp.asarray(x), jnp.asarray(g))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    em1 = emit_pattern(graph, pattern, ctx=ctx,
                       schedule_override={"schedule": "streaming",
                                          "block_rows": 4,
                                          "block_cols": 256},
                       donate_into=donate)
    if em1.estimate.schedule == "streaming":
        assert em1.io_aliases            # single tile: donation taken
        (y1,) = em1.fn(jnp.asarray(x), jnp.asarray(g))
        np.testing.assert_allclose(np.asarray(y1), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_streaming_single_tile_row_alias_correct():
    """NC == 1: the ROW donation is legal; the kernel must still match
    the reference with the alias installed."""
    x = rng.standard_normal((8, 256)).astype(np.float32)
    s = rng.standard_normal((8, 1)).astype(np.float32)
    graph = trace(_row_fn, x, s)
    ctx = CostContext(graph)
    pattern = frozenset(graph.fusible_nodes())
    s_id = graph.inputs[1]
    em = emit_pattern(graph, pattern, ctx=ctx,
                      schedule_override={"schedule": "streaming",
                                         "block_rows": 4,
                                         "block_cols": 256},
                      donate_into=frozenset({s_id}))
    assert em.estimate.schedule == "streaming"
    assert em.io_aliases
    (y,) = em.fn(jnp.asarray(x), jnp.asarray(s))
    ref = _row_fn(jnp.asarray(x), jnp.asarray(s))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_onepass_alias_behavior_unchanged():
    """The onepass path keeps its existing (legal) aliasing."""
    x = rng.standard_normal((8, 128)).astype(np.float32)
    g = np.ones(128, np.float32)
    graph = trace(_full_fn, x, g)
    ctx = CostContext(graph)
    pattern = frozenset(graph.fusible_nodes())
    x_id = graph.inputs[0]
    em = emit_pattern(graph, pattern, ctx=ctx,
                      donate_into=frozenset({x_id}))
    if em.estimate.schedule == "onepass":
        assert em.io_aliases
        (y,) = em.fn(jnp.asarray(x), jnp.asarray(g))
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(_full_fn(jnp.asarray(x),
                                               jnp.asarray(g))),
            rtol=1e-5, atol=1e-5)
