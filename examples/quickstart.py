"""Quickstart: stitch a memory-intensive function into one Pallas kernel.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stitched_jit


def layer_norm(x, gamma, beta):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + 1e-6) * gamma + beta


def main():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((4096, 1024)).astype(np.float32)
    g = rng.standard_normal(1024).astype(np.float32)
    b = rng.standard_normal(1024).astype(np.float32)

    # 1. wrap -> trace -> explore -> plan -> emit stitched kernels
    fused = stitched_jit(layer_norm)
    y = fused(x, g, b)
    assert np.allclose(np.asarray(y), np.asarray(layer_norm(x, g, b)),
                       atol=1e-4)

    # 2. inspect what the compiler did (paper Fig. 1: 16 ops -> 1 kernel)
    rep = fused.report(x, g, b)
    s = rep.stats
    print(f"ops in graph:            {s.n_fusible}")
    print(f"kernels unfused (TF):    {s.n_kernels_unfused}")
    print(f"kernels stitched (FS):   {s.n_kernels_stitched}")
    print(f"  of which Pallas:       {rep.n_pallas} (block composition)")
    print(f"HBM traffic unfused:     {s.hbm_bytes_unfused/2**20:.1f} MiB")
    print(f"HBM traffic stitched:    {s.hbm_bytes_stitched/2**20:.1f} MiB "
          f"({s.hbm_bytes_unfused/s.hbm_bytes_stitched:.1f}x less)")
    print(f"VMEM scratch (shared):   {rep.scratch_bytes} B/row "
          f"vs naive {rep.scratch_naive_bytes} B/row (paper §4.4)")
    print(f"plan time:               {rep.plan_time_s*1e3:.0f} ms "
          f"(tune once, run many)")

    # 3. gradients flow through stitched kernels
    fused_d = stitched_jit(layer_norm, differentiable=True)
    grads = jax.grad(lambda *a: jnp.sum(fused_d(*a) ** 2), argnums=(1, 2))(
        x, g, b)
    print(f"grad check: dgamma norm = {float(jnp.linalg.norm(grads[0])):.2f}")


if __name__ == "__main__":
    main()
