"""Batched serving example: prefill a batch of prompts, decode with
KV/SSM caches, compare dense vs attention-free decode behavior.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import build_model


def main():
    rng = np.random.default_rng(0)
    for arch in ("llama3.2-3b", "mamba2-370m"):
        cfg = get_config(arch).reduced()
        mdl = build_model(cfg, fusion_mode="xla")
        params = mdl.init(jax.random.PRNGKey(0))

        B, S, G = 4, 48, 24
        prompts = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)

        t0 = time.perf_counter()
        seqs = generate(mdl, params, prompts, G)
        dt = time.perf_counter() - t0
        assert seqs.shape == (B, S + G)
        cache_kind = "SSM state (O(1) per token)" if cfg.family == "ssm" \
            else "KV cache (O(S) per token)"
        print(f"{arch:16s} batch={B} prompt={S} gen={G}: {dt:5.1f}s "
              f"| {cache_kind}")
        print(f"  sample continuation: {seqs[0, S:S+8].tolist()}")


if __name__ == "__main__":
    main()
