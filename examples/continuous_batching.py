"""Continuous-batching serving on the stitched path: requests of mixed
lengths share a fixed slot pool; finished slots are refilled mid-flight
without pausing in-flight requests.  Prompt lengths canonicalize onto
the serving bucket ladder, so the 7-length mix below compiles once per
bucket, and prefill + the vmap'd decode wave each dispatch as one
beam-searched, plan-cached stitched schedule.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import ContinuousBatcher


def main():
    cfg = get_config("llama3.2-3b").reduced()
    mdl = build_model(cfg)            # fusion_mode="stitched" by default
    params = mdl.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    server = ContinuousBatcher(mdl, params, n_slots=3, max_len=96)
    rids = []
    for i in range(7):  # 7 requests > 3 slots -> mid-flight refills
        prompt = rng.integers(0, cfg.vocab_size, 6 + 3 * i).astype(np.int32)
        rids.append(server.submit(prompt, max_new=8))

    t0 = time.perf_counter()
    results = server.run()
    dt = time.perf_counter() - t0

    for rid in rids:
        print(f"req {rid}: {results[rid]}")
    print(f"\n{len(rids)} requests on {server.n_slots} slots "
          f"in {dt:.1f}s (stitched dispatch, compile counts: "
          f"{server.compile_counts()})")
    print(server.stats.summary())


if __name__ == "__main__":
    main()
