"""Continuous-batching serving: requests of mixed lengths share a fixed
slot pool; finished slots are refilled mid-flight without pausing
in-flight requests.

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import ContinuousBatcher


def main():
    cfg = get_config("llama3.2-3b").reduced()
    mdl = build_model(cfg, fusion_mode="xla")
    params = mdl.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    server = ContinuousBatcher(mdl, params, n_slots=3, max_len=96)
    rids = []
    for i in range(7):  # 7 requests > 3 slots -> mid-flight refills
        prompt = rng.integers(0, cfg.vocab_size, 6 + 3 * i).astype(np.int32)
        rids.append(server.submit(prompt, max_new=8))

    t0 = time.perf_counter()
    results = server.run()
    dt = time.perf_counter() - t0

    for rid in rids:
        print(f"req {rid}: {results[rid]}")
    s = server.stats
    print(f"\n{len(rids)} requests on {server.n_slots} slots: "
          f"{s.prefills} prefills, {s.decode_waves} decode waves, "
          f"{s.tokens_out} tokens in {dt:.1f}s ({s.tokens_out/dt:.1f} tok/s "
          f"incl. compile)")


if __name__ == "__main__":
    main()
