"""Compiler walkthrough: what the fusion explorer + code generator do to
a real transformer sub-block (gemma-style RMSNorm + GeGLU epilogue).

Shows: traced IR, XLA-baseline plan vs FusionStitching plan, chosen
schedules, VMEM scratch sharing, and the cost-model's view.

    PYTHONPATH=src python examples/compiler_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import best_estimate, make_plan, plan_stats, trace
from repro.core.memory_planner import plan_scratch
from repro.core.planner import plan_latency, xla_baseline_plan
from repro.core.rowspec import analyze


def gemma_epilogue(x, g_norm, h_gate, h_up):
    """RMSNorm -> tanh-GELU gate * up (expensive-ew mid-chain, paper §4.1)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    xn = x * jax.lax.rsqrt(ms + 1e-6) * g_norm
    gate = 0.5 * h_gate * (1 + jnp.tanh(
        0.79788456 * (h_gate + 0.044715 * h_gate ** 3)))
    return xn + gate * h_up


def main():
    rng = np.random.default_rng(0)
    B, C = 8192, 3072
    x = rng.standard_normal((B, C)).astype(np.float32)
    g = rng.standard_normal(C).astype(np.float32)
    hg = rng.standard_normal((B, C)).astype(np.float32)
    hu = rng.standard_normal((B, C)).astype(np.float32)

    G = trace(gemma_epilogue, x, g, hg, hu)
    print("=== traced IR ===")
    print(G.pprint())

    xla = xla_baseline_plan(G)
    fs = make_plan(G)
    sx = plan_stats(G, xla, composition="thread")
    sf = plan_stats(G, fs)
    print("\n=== plans ===")
    print(f"XLA baseline : {sx.n_kernels_stitched} kernels, "
          f"{sx.hbm_bytes_stitched/2**20:.0f} MiB traffic "
          f"(tanh mid-chain forces a split)")
    print(f"FusionStitch : {sf.n_kernels_stitched} kernels, "
          f"{sf.hbm_bytes_stitched/2**20:.0f} MiB traffic")

    print("\n=== per-pattern schedule choice (latency-evaluator §4.3) ===")
    for pat in fs.patterns:
        est = best_estimate(G, pat.members)
        info = analyze(G, pat.members)
        line = (f"pattern {sorted(pat.members)[:6]}..: schedule={est.schedule} "
                f"block_rows={est.block_rows} "
                f"modeled={est.latency_s*1e6:.0f}us")
        if info is not None:
            scr = plan_scratch(G, pat.members, info)
            line += (f" | scratch {scr.total_bytes}B/row "
                     f"(naive {scr.naive_bytes}B, "
                     f"reuse x{1/max(scr.reuse_ratio,1e-9):.1f})")
        print(line)

    print("\n=== modeled end-to-end (TPU v5e terms) ===")
    t_x = plan_latency(G, xla, composition="thread")
    t_f = plan_latency(G, fs)
    print(f"XLA {t_x*1e6:.0f}us vs FS {t_f*1e6:.0f}us "
          f"-> {t_x/t_f:.2f}x (paper reports 1.45x avg end-to-end)")


if __name__ == "__main__":
    main()
