"""End-to-end training driver example.

Default (CPU-friendly): a ~13M-param llama3.2-family model, 200 steps of
AdamW on the synthetic pipeline with checkpoint/restart enabled — loss
drops by >1.5 nats.  ``--hundred-m`` scales the same config to ~100M
params (same code path; a few hundred steps take hours on this 1-core
host, minutes on any accelerator).

    PYTHONPATH=src python examples/train_e2e.py [--steps 200] [--hundred-m]
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokens
from repro.launch.train import build_trainer
from repro.runtime import RestartableLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    args = ap.parse_args()

    base = get_config("llama3.2-3b")
    if args.hundred_m:
        cfg = base.reduced(d_model=512, n_layers=8, n_heads=8, n_kv_heads=4,
                           head_dim=64, d_ff=2048, vocab_size=32000,
                           max_seq=2048)
    else:
        cfg = base.reduced(d_model=256, n_layers=4, n_heads=8, n_kv_heads=4,
                           head_dim=32, d_ff=1024, vocab_size=8192,
                           max_seq=1024)

    mdl, init_state, train_step = build_trainer(
        cfg, fusion_mode="xla", lr=1e-3, total_steps=args.steps)
    print(f"params: {mdl.param_count()/1e6:.1f}M")

    data = SyntheticTokens(
        DataConfig(seed=0, global_batch=args.batch, seq_len=args.seq), cfg)
    state = init_state(jax.random.PRNGKey(0))
    loop = RestartableLoop(args.ckpt_dir, ckpt_every=50)

    losses = []

    def on_step(step, state, dt, slow):
        m = train_step.last_metrics
        losses.append(m["loss"])
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={m['loss']:.4f} "
                  f"lr={m['lr']:.2e} {dt*1e3:6.0f}ms", flush=True)

    t0 = time.perf_counter()
    state, monitor = loop.run(state, data, train_step, args.steps,
                              on_step=on_step)
    dt = time.perf_counter() - t0
    print(f"\n{args.steps} steps in {dt:.0f}s "
          f"({args.batch*args.seq*args.steps/dt:.0f} tok/s)")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(drop {losses[0]-losses[-1]:.2f} nats)")
    assert losses[-1] < losses[0] - 1.0, "training should reduce loss"


if __name__ == "__main__":
    main()
